"""Provider-calibrated billing engine: rounding/censoring math, the
ideal-profile bitwise guarantee on both engines, registry/CLI errors, and
the oracle-vs-fluid billed-cost parity band."""

import dataclasses
import math

import numpy as np
import pytest

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.runspec import RunSpec
from repro.core.trace import TraceConfig, synthesize
from repro.fleet import (AWS_LAMBDA, GCR, IDEAL, BillingProfile, NodeType,
                         apply_throttle, bill_sim, cost_from_sim,
                         cost_report, get_profile, list_profiles,
                         resolve_profile)
from repro.fleet.billing import _norm_ppf
from repro.scenarios import run_scenario
from repro.scenarios.runner import billed_parity

TC = TraceConfig(num_functions=40, duration_s=600, target_total_rps=8,
                 seed=11)


@pytest.fixture(scope="module")
def trace():
    return synthesize(TC)


# ---------------------------------------------------------------------------
# cost_report edge cases (the pre-billing layer the profiles delegate to)
# ---------------------------------------------------------------------------


def test_spot_seconds_clamped_to_node_seconds():
    # a reporting glitch claiming more spot-seconds than node-seconds must
    # bill the whole fleet at the spot rate, never go negative on-demand
    r = cost_report(node_seconds=3600.0, cpu_worker_overhead_s=0.0,
                    cpu_master_overhead_s=0.0, idle_node_share=0.0,
                    completed=10, node_type=NodeType(price_per_hour=1.0),
                    spot_node_seconds=7200.0)
    r_exact = cost_report(node_seconds=3600.0, cpu_worker_overhead_s=0.0,
                          cpu_master_overhead_s=0.0, idle_node_share=0.0,
                          completed=10,
                          node_type=NodeType(price_per_hour=1.0),
                          spot_node_seconds=3600.0)
    assert r.node_cost == r_exact.node_cost
    assert r.node_cost >= 0.0


def test_zero_node_hours_blended_rate():
    # no node-seconds: the blended churn rate falls back to on-demand
    # instead of dividing by zero, and the churn bill stays finite
    r = cost_report(node_seconds=0.0, cpu_worker_overhead_s=360.0,
                    cpu_master_overhead_s=0.0, idle_node_share=0.0,
                    completed=5, node_type=NodeType(price_per_hour=2.0,
                                                    vcpus=8.0))
    assert math.isfinite(r.churn_cost)
    assert r.churn_cost == pytest.approx((360.0 / 3600.0) * (2.0 / 8.0))


def test_zero_completions_cost_is_nan_labeled():
    # a window that completed nothing reports NaN $/1M (labeled, like the
    # ``dropped`` column), not a figure divided by a phantom request
    r = cost_report(node_seconds=3600.0, cpu_worker_overhead_s=0.0,
                    cpu_master_overhead_s=0.0, idle_node_share=0.0,
                    completed=0)
    assert math.isnan(r.cost_per_million)
    assert math.isfinite(r.total_cost)
    b = IDEAL.bill(node_seconds=3600.0, cpu_worker_overhead_s=0.0,
                   cpu_master_overhead_s=0.0, idle_node_share=0.0,
                   completed=0)
    assert math.isnan(b.cost_per_million)


# ---------------------------------------------------------------------------
# duration billing: rounding, censoring, and the analytic expectation
# ---------------------------------------------------------------------------


def test_min_billed_duration_censors_short_requests():
    p = BillingProfile(name="t", rounding_s=0.1, min_billed_s=0.1)
    assert p.billed_seconds(0.003) == pytest.approx(0.1)   # d < minimum
    assert p.billed_seconds(0.101) == pytest.approx(0.2)   # rounds up
    # an exact multiple must NOT round up one extra step via float noise
    assert p.billed_seconds(0.1) == pytest.approx(0.1)
    assert p.billed_seconds(0.3) == pytest.approx(0.3)


def test_ideal_billed_seconds_is_identity():
    d = np.array([0.0007, 0.02, 1.5, 29.9])
    assert np.array_equal(IDEAL.billed_seconds(d), d)


def test_norm_ppf_matches_standard_quantiles():
    assert _norm_ppf(np.array([0.5]))[0] == pytest.approx(0.0, abs=1e-9)
    assert _norm_ppf(np.array([0.975]))[0] == pytest.approx(1.959964,
                                                            abs=1e-5)
    assert _norm_ppf(np.array([0.001]))[0] == pytest.approx(-3.090232,
                                                            abs=1e-5)


def test_azure_minimum_bill_censors_hard():
    # the Consumption plan's 100 ms floor on 1 ms granularity: a 3 ms
    # execution bills 100 ms, a 101 ms one bills exactly 101 ms
    azure = get_profile("azure_functions")
    assert azure.min_billed_s == pytest.approx(0.1)
    assert azure.billed_seconds(0.003) == pytest.approx(0.1)
    assert azure.billed_seconds(0.101) == pytest.approx(0.101)
    assert azure.per_request > 0.0 and azure.per_gb_s > 0.0
    # no warm tier and no throttle on the Consumption plan
    assert azure.warm_gb_s_rate == 0.0 and azure.throttle_full_mb == 0.0


def test_azure_registration_leaves_ideal_bitwise(trace):
    # bitwise-ideal regression guard: registering azure_functions must not
    # perturb the ideal profile's bill by a single ulp
    kw = dict(node_seconds=5432.1, cpu_worker_overhead_s=321.0,
              cpu_master_overhead_s=77.7, idle_node_share=0.4,
              completed=1234, node_type=NodeType(price_per_hour=0.7),
              spot_node_seconds=1000.0)
    base = cost_report(**kw)
    bill = IDEAL.with_spot_discount(0.0).bill(**kw)
    for k in ("node_hours", "node_cost", "master_cost", "total_cost",
              "cost_per_million"):
        assert getattr(bill, k) == getattr(base, k), k
    # and ideal duration billing stays the identity
    d = np.asarray(trace.dur[:64])
    assert np.array_equal(IDEAL.billed_seconds(d), d)


@pytest.mark.parametrize("profile", [AWS_LAMBDA, GCR,
                                     get_profile("azure_functions")])
def test_expected_billing_matches_exact_rounding_on_trace(trace, profile):
    # the fluid side's analytic expectation vs the oracle side's exact
    # per-record rounding, on the SAME sampled durations: the trace's
    # durations are draws from the clipped lognormal the expectation
    # integrates, so the totals agree to sampling error
    counts = np.bincount(trace.fn, minlength=trace.num_functions)
    exact = np.zeros(trace.num_functions)
    np.add.at(exact, trace.fn, profile.billed_seconds(trace.dur))
    expect = profile.expected_billed_seconds(trace.profile.dur_median,
                                             trace.profile.dur_sigma)
    gap = abs(exact.sum() - (counts * expect).sum()) / exact.sum()
    assert gap < 0.05


def test_billed_weights_use_configured_memory(trace):
    w = AWS_LAMBDA.billed_weights(trace.profile)
    e = AWS_LAMBDA.expected_billed_seconds(trace.profile.dur_median,
                                           trace.profile.dur_sigma)
    assert np.allclose(w, e * trace.profile.memory_mb / 1024.0)


# ---------------------------------------------------------------------------
# cpu throttle
# ---------------------------------------------------------------------------


def test_throttle_identity_under_ideal(trace):
    assert apply_throttle(trace, IDEAL) is trace
    assert apply_throttle(trace, GCR) is trace     # whole-vCPU: no term


def test_throttle_stretches_and_caps(trace):
    out = apply_throttle(trace, AWS_LAMBDA)
    assert out is not trace
    f = AWS_LAMBDA.throttle_factor(trace.profile.memory_mb)
    assert np.all(f >= 1.0) and np.all(f <= AWS_LAMBDA.throttle_cap)
    assert np.all(out.dur >= trace.dur - 1e-12)
    assert np.allclose(out.dur,
                       np.minimum(trace.dur * f[trace.fn], 30.0))
    # full-vCPU memory is not throttled at all
    assert AWS_LAMBDA.throttle_factor(np.array([1769.0, 4096.0]))\
        .max() == 1.0


# ---------------------------------------------------------------------------
# registry / resolution / CLI
# ---------------------------------------------------------------------------


def test_registry_lists_and_friendly_error():
    assert {"ideal", "aws_lambda", "gcr",
            "azure_functions"} <= set(list_profiles())
    with pytest.raises(KeyError, match="registered"):
        get_profile("azure")


def test_resolve_profile_semantics():
    tiered = IDEAL.with_spot_discount(0.65)
    # None -> the context default, verbatim
    assert resolve_profile(None, tiered) is tiered
    # a NAME inherits the default's spot discount (tier = workload state)
    by_name = resolve_profile("aws_lambda", tiered)
    assert by_name.spot_discount == 0.65
    assert by_name.per_gb_s == AWS_LAMBDA.per_gb_s
    # a profile OBJECT is used verbatim, discount and all
    assert resolve_profile(GCR, tiered) is GCR


def test_cli_unknown_billing_exits_2(capsys):
    from repro.launch.scenarios import main
    assert main(["--scenario", "cold_tail", "--billing", "nope"]) == 2
    err = capsys.readouterr().err
    assert "aws_lambda" in err and "gcr" in err
    assert "azure_functions" in err    # new profiles list automatically
    from repro.launch.frontier import main as fmain
    assert fmain(["--scenario", "cold_tail", "--billing", "nope"]) == 2


# ---------------------------------------------------------------------------
# the ideal-profile bitwise regression, both engines
# ---------------------------------------------------------------------------


def test_ideal_bill_is_bitwise_cost_report():
    kw = dict(node_seconds=5432.1, cpu_worker_overhead_s=321.0,
              cpu_master_overhead_s=77.7, idle_node_share=0.4,
              completed=1234, node_type=NodeType(price_per_hour=0.7),
              spot_node_seconds=1000.0)
    base = cost_report(**kw)
    bill = IDEAL.with_spot_discount(0.0).bill(**kw)
    for k in ("node_hours", "node_cost", "master_cost", "churn_cost",
              "idle_cost", "total_cost", "cost_per_million"):
        assert getattr(bill, k) == getattr(base, k), k
    assert bill.request_cost == 0.0 and bill.duration_cost == 0.0
    assert bill.warm_pool_cost == 0.0


def test_ideal_oracle_bill_is_bitwise_cost_from_sim(trace):
    res = EventSim(trace, Cluster(4),
                   lambda f: __import__("repro.core.policies",
                                        fromlist=["SyncKeepalivePolicy"])
                   .SyncKeepalivePolicy(keepalive_s=120),
                   SimConfig()).run()
    base = cost_from_sim(res)
    bill = bill_sim(res, trace, IDEAL)
    for k in ("node_cost", "total_cost", "cost_per_million", "idle_cost"):
        assert getattr(bill, k) == getattr(base, k), k


def test_ideal_billing_leaves_both_engines_bitwise_unchanged():
    # billing="ideal" must not perturb a single metric on either engine:
    # no throttle, weight-1 node bill, zero provider terms
    plain = run_scenario("cold_tail",
                         spec=RunSpec(scale=0.1, force_oracle=True))
    billed = run_scenario("cold_tail",
                          spec=RunSpec(scale=0.1, force_oracle=True,
                                       billing="ideal"))
    for p, b in zip(plain, billed):
        assert p["engine"] == b["engine"]
        for k in ("slowdown_geomean_p99", "normalized_memory",
                  "creation_rate", "cpu_overhead"):
            assert p[k] == b[k], (b["engine"], k)
        # a bill counts whole requests: the fluid leg's fractional
        # completion expectation is truncated, nothing else moves
        assert b["completed"] == int(p["completed"])
        # and the billed total is bitwise the ideal cost layer's total
        assert b["billing"] == "ideal"
        assert math.isfinite(b["total_cost"])


def test_provider_billing_emits_provider_terms():
    rows = run_scenario("cold_tail",
                        spec=RunSpec(scale=0.1, force_oracle=True,
                                     billing="aws_lambda"))
    assert len(rows) == 2
    for r in rows:
        assert r["billing"] == "aws_lambda"
        assert r["request_cost"] > 0.0
        assert r["duration_cost"] > 0.0
        assert r["billed_gb_s"] > 0.0
        # serverless profile: the node-hour axis is not billed
        assert r["node_cost"] == 0.0


# ---------------------------------------------------------------------------
# the oracle-vs-fluid billed-cost parity band (the acceptance gate)
# ---------------------------------------------------------------------------


def test_billed_parity_cold_tail_quick():
    gaps = billed_parity("cold_tail", "aws_lambda", scale=0.25)
    assert gaps["total_cost"] <= 0.15
    assert gaps["billed_gb_s"] <= 0.15


@pytest.mark.slow
@pytest.mark.parametrize("provider", ["aws_lambda", "gcr"])
def test_billed_parity_all_scenarios(provider):
    from repro.scenarios import get_scenario, list_scenarios
    for name in list_scenarios():
        if get_scenario(name).rate_trace:
            continue   # fluid-only by construction: no oracle leg to bill
        gaps = billed_parity(name, provider, scale=0.25)
        assert gaps["total_cost"] <= 0.15, (name, provider, gaps)


# ---------------------------------------------------------------------------
# fig13 machinery
# ---------------------------------------------------------------------------


def test_fig13_rank_and_front_shift_math():
    from benchmarks.fig13_billing_delta import front_shift, rank_shift
    a = [{"point_id": i, "cost_per_million": c,
          "slowdown_geomean_p99": 1.0 + i}
         for i, c in enumerate([1.0, 2.0, 3.0])]
    b = [{"point_id": i, "cost_per_million": c,
          "slowdown_geomean_p99": 1.0 + i}
         for i, c in enumerate([3.0, 2.0, 1.0])]
    assert rank_shift(a, a) == 0.0
    assert rank_shift(a, b) == 1.0          # full reversal
    assert front_shift(a, a) == 0.0


def test_spot_discount_only_rebills_spot_tier():
    p = dataclasses.replace(AWS_LAMBDA, node_hour_weight=1.0)\
        .with_spot_discount(0.65)
    kw = dict(cpu_worker_overhead_s=0.0, cpu_master_overhead_s=0.0,
              idle_node_share=0.0, completed=10,
              node_type=NodeType(price_per_hour=1.0))
    mixed = p.bill(node_seconds=7200.0, spot_node_seconds=3600.0, **kw)
    # one on-demand hour + one spot hour at 35%
    assert mixed.node_cost == pytest.approx(1.0 + 0.35)

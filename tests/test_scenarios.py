"""Scenario engine: registry, trace transforms, chunked-vs-full simulator
agreement, and the oracle-vs-simjax parity acceptance band."""

import dataclasses

import numpy as np
import pytest

from repro.core.runspec import RunSpec
from repro.core.simjax import (JaxFleet, JaxPolicy, simulate, simulate_chunked,
                               summarize)
from repro.core.trace import TraceConfig, merge_traces, synthesize
from repro.scenarios import (BurstInject, PolicySpec, RateScale, Scenario,
                             Splice, TenantMerge, TimeWarp, get_scenario,
                             list_scenarios, parity_report, run_scenario)

TC = TraceConfig(num_functions=50, duration_s=900, target_total_rps=8, seed=7)


@pytest.fixture(scope="module")
def trace():
    return synthesize(TC)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_catalogue():
    names = list_scenarios()
    assert len(names) >= 5
    assert {"diurnal", "flash_crowd", "cold_tail", "multi_tenant",
            "fig9_production", "fleet_cost_stress"} <= set(names)
    for n in names:
        sc = get_scenario(n)
        assert sc.description and sc.figure
    with pytest.raises(KeyError):
        get_scenario("not_a_scenario")


def test_fig9_scenario_is_production_scale():
    sc = get_scenario("fig9_production")
    assert sc.base.num_functions == 2000
    assert not sc.oracle_ok            # discrete replay infeasible at 1.0x


def test_scenario_scaling_preserves_shape():
    sc = get_scenario("diurnal")
    small = sc.build_trace(scale=0.1)
    assert small.num_functions == int(round(sc.base.num_functions * 0.1))
    assert small.duration_s == sc.base.duration_s * 0.1
    assert len(small) > 0


# ---------------------------------------------------------------------------
# transforms
# ---------------------------------------------------------------------------


def test_time_warp_preserves_load_and_order(trace):
    rng = np.random.default_rng(0)
    out = TimeWarp(period_frac=0.5, depth=0.8)(trace, TC, rng)
    assert len(out) == len(trace)                 # no invocations lost
    assert (np.diff(out.t) >= 0).all()            # still sorted
    assert out.t.min() >= 0 and out.t.max() <= trace.duration_s
    # intensity actually varies: quarter-window arrival counts spread out
    q = np.histogram(out.t, bins=8)[0]
    q0 = np.histogram(trace.t, bins=8)[0]
    assert q.std() > q0.std()


def test_rate_scale_up_and_down(trace):
    rng = np.random.default_rng(0)
    up = RateScale(2.0)(trace, TC, rng)
    down = RateScale(0.5)(trace, TC, rng)
    assert len(up) == pytest.approx(2 * len(trace), rel=0.05)
    assert len(down) == pytest.approx(0.5 * len(trace), rel=0.1)
    assert (np.diff(up.t) >= 0).all()


def test_burst_inject_adds_load_only_in_window(trace):
    rng = np.random.default_rng(0)
    tf = BurstInject(at_frac=0.5, width_frac=0.1, factor=4.0, top_k=5)
    out = tf(trace, TC, rng)
    t0, t1 = 0.5 * trace.duration_s, 0.6 * trace.duration_s
    inside = ((out.t >= t0) & (out.t < t1)).sum()
    inside_before = ((trace.t >= t0) & (trace.t < t1)).sum()
    outside = ((out.t < t0) | (out.t >= t1)).sum()
    outside_before = ((trace.t < t0) | (trace.t >= t1)).sum()
    assert inside > inside_before                 # burst added load
    assert outside == outside_before              # only in the window


def test_splice_keeps_head_replaces_tail(trace):
    rng = np.random.default_rng(0)
    out = Splice(at_frac=0.5)(trace, TC, rng)
    cut = 0.5 * trace.duration_s
    head, head0 = out.t[out.t < cut], trace.t[trace.t < cut]
    assert np.array_equal(head, head0)            # head untouched
    # tail is a different realization of the same population
    assert not np.array_equal(out.t[out.t >= cut], trace.t[trace.t >= cut])
    assert out.num_functions == trace.num_functions


def test_tenant_merge_rekeys_second_population(trace):
    rng = np.random.default_rng(0)
    out = TenantMerge(num_functions_frac=0.5, rps_frac=0.5)(trace, TC, rng)
    assert out.num_functions == trace.num_functions + TC.num_functions // 2
    assert out.fn.max() >= trace.num_functions    # tenant B ids shifted
    assert len(out) > len(trace)
    assert (np.diff(out.t) >= 0).all()


def test_merge_traces_interleaves():
    a, b = synthesize(TC), synthesize(dataclasses.replace(TC, seed=9))
    m = merge_traces(a, b)
    assert len(m) == len(a) + len(b)
    assert m.num_functions == a.num_functions + b.num_functions


# ---------------------------------------------------------------------------
# chunked scan vs full-history scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy,fleet", [
    (JaxPolicy(kind=0, keepalive_s=120), None),
    (JaxPolicy(kind=1, window_s=60, target=0.7), None),
    (JaxPolicy(kind=1, window_s=60, target=0.7),
     JaxFleet(node_memory_mb=8192.0, min_nodes=1, max_nodes=32)),
])
def test_chunked_matches_full_history(trace, policy, fleet):
    """Same step math, segmented time axis + in-carry summary stats: the
    chunked path must reproduce the full-history summary (sum-based metrics
    to float tolerance, histogram-based slowdown within binning error)."""
    full = summarize(simulate(trace, policy, fleet=fleet))
    chunk = simulate_chunked(trace, policy, fleet=fleet, chunk_ticks=257)
    for key in ("normalized_memory", "creation_rate", "cpu_overhead",
                "instances_mean", "nodes_mean", "node_seconds", "completed"):
        assert chunk[key] == pytest.approx(full[key], rel=1e-3), key
    assert chunk["slowdown_geomean_p99"] == pytest.approx(
        full["slowdown_geomean_p99"], rel=0.05)


def test_chunked_handles_uneven_tail_chunk(trace):
    a = simulate_chunked(trace, JaxPolicy(kind=0, keepalive_s=120),
                         chunk_ticks=900)
    b = simulate_chunked(trace, JaxPolicy(kind=0, keepalive_s=120),
                         chunk_ticks=128)       # 900 = 7*128 + 4 (padded)
    for key in ("normalized_memory", "creation_rate", "completed"):
        assert a[key] == pytest.approx(b[key], rel=1e-4), key


def test_chunked_production_scale_small():
    """A 1000-function slice of the Fig. 9 replay runs through the chunked
    scan in the fast tier (the full 2000-fn / 3.5M-invocation version is
    slow-marked below)."""
    sc = get_scenario("fig9_production")
    trace = sc.build_trace(scale=0.5)
    s = simulate_chunked(trace, sc.policy.to_jax(), num_nodes=sc.num_nodes,
                         chunk_ticks=sc.chunk_ticks)
    assert np.isfinite(s["slowdown_geomean_p99"])
    # metrics cover the post-warmup half of the run
    assert s["completed"] > 0.3 * len(trace)


@pytest.mark.slow
def test_chunked_production_scale_full():
    """Acceptance: the 2000-function / ~3.5M-invocation scenario completes
    via the chunked scan without materializing per-tick histories."""
    sc = get_scenario("fig9_production")
    trace = sc.build_trace()
    assert trace.num_functions == 2000
    assert len(trace) > 3_000_000
    s = simulate_chunked(trace, sc.policy.to_jax(), num_nodes=sc.num_nodes,
                         chunk_ticks=sc.chunk_ticks)
    assert np.isfinite(s["slowdown_geomean_p99"])
    assert s["normalized_memory"] > 1.0


# ---------------------------------------------------------------------------
# one Scenario spec -> both engines, with parity (acceptance criterion)
# ---------------------------------------------------------------------------

PARITY_SCENARIOS = ["diurnal", "flash_crowd", "cold_tail", "multi_tenant",
                    "fleet_cost_stress"]


@pytest.mark.parametrize("name", PARITY_SCENARIOS)
def test_scenario_parity_oracle_vs_simjax(name):
    """Each oracle-feasible scenario replays through BOTH engines from one
    spec with <= 15% relative gap on slowdown / normalized memory /
    creation rate (the hybrid-methodology acceptance band)."""
    rows = run_scenario(name, spec=RunSpec(scale=0.25))
    assert {r["engine"] for r in rows} == {"eventsim", "simjax"}
    gaps = parity_report(rows)
    for metric, gap in gaps.items():
        assert gap <= 0.15, (name, metric, gap, rows)


@pytest.mark.slow
def test_fig9_scenario_parity_at_reduced_scale():
    """The production replay's oracle leg only runs shrunk; slowdown and
    memory hold the 15% band there (creation rate is out-of-band for this
    strongly bursty trace under the Poisson-renewal expiry model — a
    documented limitation, see EXPERIMENTS.md)."""
    rows = run_scenario("fig9_production", spec=RunSpec(scale=0.25))
    assert {r["engine"] for r in rows} == {"eventsim", "simjax"}
    gaps = parity_report(rows)
    assert gaps["slowdown_geomean_p99"] <= 0.15
    assert gaps["normalized_memory"] <= 0.15


def test_fig9_oracle_skipped_at_full_scale():
    rows = run_scenario("fig9_production",
                        spec=RunSpec(engines=("eventsim",), scale=1.0))
    assert rows == []                  # infeasible leg skipped, not crashed


def test_policyspec_bridges_both_engines():
    sync, asyn = PolicySpec(kind="sync", keepalive_s=42), \
        PolicySpec(kind="async", window_s=30, target=0.5)
    assert sync.to_jax().kind == 0 and sync.to_jax().keepalive_s == 42
    assert asyn.to_jax().kind == 1 and asyn.to_jax().target == 0.5
    assert sync.factory()(0).keepalive(0.0) == 42
    assert asyn.factory()(0).window_s == 30
    with pytest.raises(ValueError):
        PolicySpec(kind="bogus").factory()


def test_runner_row_schema():
    rows = run_scenario("cold_tail",
                        spec=RunSpec(engines=("simjax",), scale=0.1))
    assert len(rows) == 1
    r = rows[0]
    assert {"scenario", "engine", "scale", "invocations", "wall_s",
            "slowdown_geomean_p99", "normalized_memory",
            "creation_rate"} <= set(r)
    assert r["engine"] == "simjax"

import os

# Tests run on the single real CPU device (the dry-run, and only the dry-run,
# forces 512 host devices — see repro.launch.dryrun).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

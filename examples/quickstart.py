"""Quickstart: replay a synthetic Azure-like trace through both autoscaling
policy families and print the paper's four metrics.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute
from repro.core.policies import (AsyncConcurrencyPolicy, HybridHistogramPolicy,
                                 SyncKeepalivePolicy)
from repro.core.trace import TraceConfig, synthesize


def main():
    trace = synthesize(TraceConfig(num_functions=150, duration_s=1800,
                                   target_total_rps=25, seed=0))
    print(f"trace: {len(trace):,} invocations over {trace.duration_s/60:.0f} min, "
          f"{trace.num_functions} functions\n")
    print(f"{'policy':34s} {'slowdown':>9s} {'norm_mem':>9s} {'create/s':>9s} "
          f"{'cpu_ovh':>8s} {'worker%':>8s}")
    for name, pf in [
        ("Kn-Sync keepalive=30s", lambda f: SyncKeepalivePolicy(30)),
        ("Kn-Sync keepalive=600s", lambda f: SyncKeepalivePolicy(600)),
        ("Kn async w=60s target=0.7", lambda f: AsyncConcurrencyPolicy(window_s=60)),
        ("Kn async w=600s target=0.7", lambda f: AsyncConcurrencyPolicy(window_s=600)),
        ("HybridHistogram (beyond-paper)", lambda f: HybridHistogramPolicy()),
    ]:
        m = compute(EventSim(trace, Cluster(8), pf, SimConfig()).run())
        print(f"{name:34s} {m.slowdown_geomean_p99:9.2f} {m.normalized_memory:9.2f} "
              f"{m.creation_rate:9.3f} {m.cpu_overhead*100:7.1f}% "
              f"{m.worker_share*100:7.0f}%")


if __name__ == "__main__":
    main()

"""Spot-fleet demo: preemptible capacity tiers under an eviction hazard,
with the bill split per tier.

Three views of the same workload (mirroring examples/fleet_autoscale.py):
  1. the discrete-event oracle with a SpotNodeFleet — the market reclaims
     spot nodes with a 2-minute notice, warm instances are evicted, their
     in-flight work re-queues, and the bill discounts only spot node-hours,
  2. the vectorized lax.scan simulator with the spot hazard as a traced
     eviction flux (the spot_aware policy family's axes),
  3. the trade-off: sweep the spot purchase fraction and watch cost fall
     while eviction-driven cold-start storms push the p99 tail up.

    PYTHONPATH=src python examples/spot_fleet.py
"""

import time

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute
from repro.core.policies import SpotAwarePolicy
from repro.core.simjax import JaxFleet, JaxPolicy, simulate, summarize
from repro.core.trace import TraceConfig, synthesize
from repro.fleet import (NodeType, PriceBook, SpotMarket, SpotNodeFleet,
                         UtilizationFleetPolicy, cost_from_sim, get_tier)

NODE = NodeType(name="worker-8", memory_mb=32_768.0, vcpus=8.0,
                price_per_hour=0.39, provision_s=60.0)
SPOT = get_tier("spot")                     # 0.35x price, hazard, 120s notice
PRICES = PriceBook(spot_discount=SPOT.discount)


def main():
    trace = synthesize(TraceConfig(num_functions=120, duration_s=1800,
                                   target_total_rps=20, seed=42))
    print(f"trace: {len(trace):,} invocations / {trace.num_functions} "
          f"functions; spot tier: {SPOT.price_multiplier:.2f}x on-demand, "
          f"{SPOT.hazard_per_hour:g} reclaims/node-hour, "
          f"{SPOT.reclaim_notice_s:g}s notice")

    # -- 1. oracle with a 60%-spot fleet -------------------------------------
    fleet = SpotNodeFleet(
        UtilizationFleetPolicy(min_nodes=1, max_nodes=32, util_target=0.7,
                               warm_frac=0.25),
        node_type=NODE, cooldown_s=120.0, spot_fraction=0.6,
        market=SpotMarket(SPOT, seed=0))
    res = EventSim(trace, Cluster(1, node_memory_mb=NODE.memory_mb),
                   lambda f: SpotAwarePolicy(
                       keepalive_s=600, spot_fraction=0.6,
                       hazard_per_hour=SPOT.hazard_per_hour),
                   SimConfig(), fleet=fleet).run()
    m = compute(res)
    bill = cost_from_sim(res, node_type=NODE, prices=PRICES)
    print(f"\noracle spot fleet: nodes_mean={m.nodes_mean:.1f} "
          f"evictions={m.node_evictions} "
          f"(spot share of node-hours "
          f"{m.spot_node_hours / max(m.node_hours, 1e-9):.0%})")
    print(f"  slowdown_p99={m.slowdown_geomean_p99:.2f} "
          f"completed={m.completed} requeued="
          f"{sum(r.requeued for r in res.records)}")
    print(f"  bill: ${bill.total_cost:.3f} -> "
          f"${bill.cost_per_million:.2f}/1M requests "
          f"(idle ${bill.idle_cost:.3f}, churn ${bill.churn_cost:.3f})")

    # -- 2. fluid twin: hazard as a traced eviction flux ---------------------
    jf = JaxFleet(node_memory_mb=NODE.memory_mb, provision_s=NODE.provision_s,
                  min_nodes=1, max_nodes=32, util_target=0.7, warm_frac=0.25,
                  cooldown_s=120.0, reclaim_notice_s=SPOT.reclaim_notice_s)
    s = summarize(simulate(trace, JaxPolicy(
        family="spot_aware", keepalive_s=600,
        extra={"spot_fraction": 0.6,
               "hazard_per_hour": SPOT.hazard_per_hour}), fleet=jf))
    print(f"\nsimjax spot fleet: nodes_mean={s['nodes_mean']:.1f} "
          f"(spot {s['spot_nodes_mean']:.1f}) "
          f"slowdown_p99={s['slowdown_geomean_p99']:.2f} "
          f"(oracle/fluid node ratio "
          f"{m.nodes_mean / max(s['nodes_mean'], 1e-9):.2f})")

    # -- 3. the spot fraction trade-off --------------------------------------
    print(f"\n{'spot_fraction':>14s} {'$/1M':>8s} {'p99 slow':>9s} "
          f"{'spot nodes':>10s}")
    t0 = time.time()
    for sf in (0.0, 0.3, 0.6, 0.9):
        s = summarize(simulate(trace, JaxPolicy(
            family="spot_aware", keepalive_s=600,
            extra={"spot_fraction": sf,
                   "hazard_per_hour": SPOT.hazard_per_hour}), fleet=jf))
        spot_s = s["spot_node_seconds"]
        od_rate = NODE.price_per_hour
        cost = ((s["node_seconds"] - spot_s) * od_rate
                + spot_s * od_rate * (1 - PRICES.spot_discount)) / 3600.0
        per_m = cost / max(s["completed"], 1) * 1e6
        print(f"{sf:14.1f} {per_m:8.2f} "
              f"{s['slowdown_geomean_p99']:9.2f} "
              f"{s['spot_nodes_mean']:10.1f}")
    print(f"({time.time() - t0:.1f}s; cheaper fleets, longer tails — "
          f"the frontier engine prices that trade, see "
          f"benchmarks/fig12_spot_frontier.py)")


if __name__ == "__main__":
    main()

"""Train a small LM for a few hundred steps with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Interrupt it at any point and re-run: it resumes from the latest
step-atomic checkpoint with an identical data stream.
"""

import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()
    train.main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--seq-len", "128", "--batch", "8",
        "--lr", "3e-3", "--warmup", "20",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "10",
    ])


if __name__ == "__main__":
    main()

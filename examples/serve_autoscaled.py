"""END-TO-END DRIVER: serve a real (reduced-config) model with batched
requests under the real autoscaling control plane.

Cold starts are genuine (weight init + XLA compile, measured), instances are
genuine model replicas with slot-based continuous batching, and the policy is
the same object the simulators use.

    PYTHONPATH=src python examples/serve_autoscaled.py [--policy async]
"""

import argparse
import time

import numpy as np

from repro.configs import get_smoke_config
from repro.core.control_plane import ControlPlane, JaxWorkerBackend
from repro.core.policies import make_policy
from repro.serving.engine import ServeRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--policy", default="sync", choices=["sync", "async", "hybrid"])
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--cc", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(param_dtype="bfloat16", remat="none")
    kw = {"container_concurrency": args.cc}
    if args.policy == "sync":
        kw["keepalive_s"] = 30.0
    elif args.policy == "async":
        kw.update(window_s=5.0, target=0.7)
    backend = JaxWorkerBackend(cfg, max_slots=args.cc, max_seq=64)
    cp = ControlPlane(backend, lambda f: make_policy(args.policy, **kw),
                      num_functions=2)

    rng = np.random.default_rng(0)
    arrivals = np.sort(rng.uniform(0, args.duration, args.requests))
    fns = rng.integers(0, 2, args.requests)
    t0 = time.monotonic()
    now = lambda: time.monotonic() - t0
    i = 0
    peak_instances = 0
    while len(cp.completed) < args.requests and now() < args.duration + 300:
        while i < len(arrivals) and arrivals[i] <= now():
            cp.submit(ServeRequest(rid=i, fn=int(fns[i]),
                                   prompt=[1 + i % 7, 2, 3],
                                   max_new_tokens=8, arrival_t=now()), now())
            i += 1
        cp.tick(now())
        peak_instances = max(peak_instances, cp.snapshot()["instances"])
        time.sleep(0.002)

    lat = np.array([r.done_t - r.arrival_t for r in cp.completed])
    cold = np.array([r.cold for r in cp.completed])
    print(f"\nserved {len(cp.completed)}/{args.requests} requests "
          f"({args.policy} policy, cc={args.cc})")
    print(f"latency: p50={np.percentile(lat, 50):.2f}s p99={np.percentile(lat, 99):.2f}s")
    print(f"cold-start fraction: {cold.mean()*100:.0f}%")
    print(f"instances created: {backend.creations} (peak concurrent {peak_instances})")
    print(f"measured cold starts (init+compile): "
          f"{', '.join(f'{c:.2f}s' for c in backend.cold_start_times[:6])}")
    sample = cp.completed[0]
    print(f"sample generation: prompt={sample.prompt} -> {sample.output}")


if __name__ == "__main__":
    main()

"""The KWOK-scale experiment (paper §3.4/§4.4): 2000 functions, ~3.5M
invocations, 50 worker nodes — real policy math, CHUNKED lax.scan workers
(summary statistics accumulate in the scan carry; no per-tick histories)
— plus a node-failure fault-tolerance demo on the event-driven oracle.

    PYTHONPATH=src python examples/large_scale_sim.py
"""

import time

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute
from repro.core.policies import SyncKeepalivePolicy
from repro.core.simjax import JaxPolicy, simulate_chunked
from repro.core.trace import TraceConfig, synthesize
from repro.scenarios import get_scenario


def main():
    # -- large scale: the fig9_production scenario, chunked scan -------------
    sc = get_scenario("fig9_production")
    trace = sc.build_trace()
    print(f"large trace: {len(trace):,} invocations, {trace.num_functions} fns")
    print(f"{'config':24s} {'slowdown':>9s} {'norm_mem':>9s} {'cpu_ovh':>8s} {'sim_time':>9s}")
    for name, pol in [
        ("sync ka=600", JaxPolicy(kind=0, keepalive_s=600)),
        ("async w=600 t=0.7", JaxPolicy(kind=1, window_s=600, target=0.7)),
        ("async w=600 t=1.0", JaxPolicy(kind=1, window_s=600, target=1.0)),
    ]:
        t0 = time.time()
        s = simulate_chunked(trace, pol, num_nodes=sc.num_nodes,
                             chunk_ticks=sc.chunk_ticks)
        print(f"{name:24s} {s['slowdown_geomean_p99']:9.2f} "
              f"{s['normalized_memory']:9.2f} {s['cpu_overhead']*100:7.1f}% "
              f"{time.time()-t0:8.1f}s")

    # -- fault tolerance: kill 2 of 8 nodes mid-run (event-driven oracle) ----
    small = synthesize(TraceConfig(num_functions=100, duration_s=1200,
                                   target_total_rps=15, seed=4))
    for name, failures in [("no failures", None),
                           ("2/8 nodes fail @600s", [(600.0, 0), (600.0, 1)])]:
        res = EventSim(small, Cluster(8), lambda f: SyncKeepalivePolicy(300),
                       SimConfig(), failures=failures).run()
        m = compute(res)
        requeued = sum(r.requeued for r in res.records)
        print(f"{name:24s} slowdown={m.slowdown_geomean_p99:6.2f} "
              f"completed={m.completed} requeued={requeued}")


if __name__ == "__main__":
    main()

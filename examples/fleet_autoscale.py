"""Two-level autoscaling demo: instance policies riding an elastic node
fleet, with the bill in dollars.

Three views of the same workload:
  1. the discrete-event oracle with a NodeFleet (provision latency, warm
     pool, drain-before-terminate) and its cost report,
  2. the vectorized lax.scan simulator with the fleet in the scan carry,
  3. the vmapped sweep: a keepalive x warm-pool frontier in one compiled vmap.

    PYTHONPATH=src python examples/fleet_autoscale.py
"""

import time

from repro.core.cluster import Cluster
from repro.core.eventsim import EventSim, SimConfig
from repro.core.metrics import compute
from repro.core.policies import AsyncConcurrencyPolicy
from repro.core.simjax import JaxFleet, JaxPolicy, simulate, summarize
from repro.core.trace import TraceConfig, synthesize
from repro.fleet import (NodeFleet, NodeType, UtilizationFleetPolicy,
                         cost_from_sim)
from repro.fleet.sweep import sweep
from repro.opt.frontier import pareto_front

NODE = NodeType(name="worker-8", memory_mb=32_768.0, vcpus=8.0,
                price_per_hour=0.39, provision_s=60.0)


def main():
    trace = synthesize(TraceConfig(num_functions=120, duration_s=1800,
                                   target_total_rps=20, seed=42))
    print(f"trace: {len(trace):,} invocations / {trace.num_functions} functions")

    # -- 1. oracle with an elastic fleet -------------------------------------
    fleet = NodeFleet(UtilizationFleetPolicy(min_nodes=1, max_nodes=32,
                                             util_target=0.7, warm_frac=0.25),
                      node_type=NODE, cooldown_s=120.0)
    res = EventSim(trace, Cluster(1, node_memory_mb=NODE.memory_mb),
                   lambda f: AsyncConcurrencyPolicy(window_s=60, target=0.7),
                   SimConfig(), fleet=fleet).run()
    m = compute(res)
    bill = cost_from_sim(res, node_type=NODE)
    print(f"\noracle fleet: nodes_mean={m.nodes_mean:.1f} "
          f"provisions={m.node_provisions} terminations={m.node_terminations}")
    print(f"  slowdown_p99={m.slowdown_geomean_p99:.2f} "
          f"completed={m.completed} dropped={res.dropped}")
    print(f"  bill: ${bill.total_cost:.3f} (nodes ${bill.node_cost:.3f} "
          f"+ master ${bill.master_cost:.3f}) -> "
          f"${bill.cost_per_million:.2f}/1M requests "
          f"(churn ${bill.churn_cost:.3f}, idle ${bill.idle_cost:.3f})")

    # -- 2. vectorized simulator, fleet in the scan carry --------------------
    s = summarize(simulate(trace, JaxPolicy(kind=1, window_s=60, target=0.7),
                           fleet=JaxFleet(node_memory_mb=NODE.memory_mb,
                                          provision_s=NODE.provision_s,
                                          min_nodes=1, max_nodes=32,
                                          util_target=0.7, warm_frac=0.25,
                                          cooldown_s=120.0)))
    print(f"\nsimjax fleet: nodes_mean={s['nodes_mean']:.1f} "
          f"slowdown_p99={s['slowdown_geomean_p99']:.2f} "
          f"(oracle/fluid node ratio "
          f"{m.nodes_mean / max(s['nodes_mean'], 1e-9):.2f})")

    # -- 3. vmapped trade-off frontier ---------------------------------------
    t0 = time.time()
    rows = sweep(trace, JaxPolicy(kind=0, keepalive_s=600),
                 JaxFleet(node_memory_mb=NODE.memory_mb,
                          provision_s=NODE.provision_s, min_nodes=1,
                          max_nodes=32, util_target=0.7, cooldown_s=120.0),
                 grid={"keepalive_s": [30.0, 120.0, 600.0, 1800.0],
                       "warm_frac": [0.0, 0.25, 0.5]},
                 node_type=NODE)
    dt = time.time() - t0
    print(f"\nsweep: {len(rows)} configs in {dt:.1f}s "
          f"({dt / len(rows) * 1e3:.0f} ms/config, one vmapped scan)")
    print(f"{'config':>24s} {'$/1M':>8s} {'p99 slow':>9s} {'nodes':>6s}")
    for r in pareto_front(rows):
        name = f"ka={r['keepalive_s']:.0f} warm={r['warm_frac']:.2f}"
        print(f"{name:>24s} {r['cost_per_million']:8.2f} "
              f"{r['slowdown_geomean_p99']:9.2f} {r['nodes_mean']:6.1f}")


if __name__ == "__main__":
    main()
